"""Recurrent cells: mLSTM / sLSTM (xLSTM, arXiv:2405.04517) and Mamba-style
selective SSM (for Hymba's parallel heads, arXiv:2411.13676).

All cells expose:
  init(key, cfg)                  -> params
  apply_seq(p, x, cfg)            -> (y, final_state)   # train/prefill
  apply_step(p, x_t, state, cfg)  -> (y_t, new_state)   # decode
  init_state(cfg, batch)          -> state pytree
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def _norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _causal_conv(x, w):
    """Depthwise causal conv. x: [B,S,C]; w: [K,C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out


# ================================================================ mLSTM =====
def mlstm_dims(cfg: ModelConfig):
    di = cfg.ssm.expand * cfg.d_model
    nh = cfg.num_heads
    dh = di // nh
    return di, nh, dh


def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, nh, dh = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    dt = cfg.dtype
    s = d ** -0.5
    si = di ** -0.5
    return {
        "ln": jnp.ones((d,), dt),
        "w_in": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dt),
        "conv": (jax.random.normal(ks[1], (cfg.ssm.conv_kernel, di)) * 0.1).astype(dt),
        "wq": (jax.random.normal(ks[2], (di, di)) * si).astype(dt),
        "wk": (jax.random.normal(ks[3], (di, di)) * si).astype(dt),
        "wv": (jax.random.normal(ks[4], (di, di)) * si).astype(dt),
        "w_if": (jax.random.normal(ks[5], (di, 2 * nh)) * si).astype(jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((nh,)), jnp.linspace(3.0, 6.0, nh)]),
        "gn": jnp.ones((di,), dt),
        "w_out": (jax.random.normal(ks[6], (di, d)) * si).astype(dt),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    di, nh, dh = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.conv_kernel - 1, di), cfg.dtype),
    }


def _mlstm_qkvgates(p, x, cfg, conv_state=None):
    di, nh, dh = mlstm_dims(cfg)
    xz = _norm(x, p["ln"]) @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)
    if conv_state is not None:  # decode: prepend cached conv inputs
        xi_full = jnp.concatenate([conv_state, xi], axis=1)
        new_conv = xi_full[:, -(cfg.ssm.conv_kernel - 1):, :]
        k = p["conv"].shape[0]
        xi = sum(xi_full[:, i:i + xi.shape[1], :] * p["conv"][i]
                 for i in range(k))
    else:
        xi = _causal_conv(xi, p["conv"])
        new_conv = None
    xi = jax.nn.silu(xi)
    b, s, _ = xi.shape

    def heads(t):
        return t.reshape(b, s, nh, dh).transpose(0, 2, 1, 3)  # [B,NH,S,DH]

    q = heads(xi @ p["wq"]).astype(jnp.float32)
    k_ = heads(xi @ p["wk"]).astype(jnp.float32) * dh ** -0.5
    v = heads(xi @ p["wv"]).astype(jnp.float32)
    gates = xi.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    ig, fg = jnp.split(gates, 2, axis=-1)        # [B,S,NH]
    log_f = -jax.nn.softplus(-fg)                # log sigmoid(f)
    return q, k_, v, ig.transpose(0, 2, 1), log_f.transpose(0, 2, 1), z, new_conv


def _mlstm_update(C, n, m, q_t, k_t, v_t, i_t, lf_t):
    """One stabilized mLSTM step. shapes: C [B,NH,DH,DH]; q/k/v [B,NH,DH];
    i/lf [B,NH]."""
    m_new = jnp.maximum(lf_t + m, i_t)
    fs = jnp.exp(lf_t + m - m_new)[..., None]
    is_ = jnp.exp(i_t - m_new)[..., None]
    C_new = fs[..., None] * C + is_[..., None] * (v_t[..., :, None] * k_t[..., None, :])
    n_new = fs * n + is_ * k_t
    num = jnp.einsum("bhij,bhj->bhi", C_new, q_t)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, q_t)),
                      jnp.exp(-m_new))[..., None]
    h = num / den
    return C_new, n_new, m_new, h


def mlstm_chunk_body(C, n, m, q, k, v, ig, lf):
    """Process one chunk of the stabilized mLSTM in parallel (TFLA-style
    chunkwise form — the per-step recurrence unrolled exactly).

    q/k/v: [B,NH,c,DH]; ig/lf: [B,NH,c]; carry C [B,NH,DH,DH], n [B,NH,DH],
    m [B,NH]. Returns (C', n', m', h [B,NH,c,DH]).

    With b_t = cumsum(lf) (inclusive) and M_t = running max of (i_j - b_j):
      m_t   = b_t + max(m_in, M_t)
      h_t   = [ Σ_{j<=t} e^{b_t-b_j+i_j-m_t} v_j (k_j.q_t)
                + e^{m_in+b_t-m_t} C_in q_t ] / den_t
    This is the oracle mirrored by kernels/mlstm (same math, same
    stabilization), and what the Pallas kernel tiles into VMEM.
    """
    c = q.shape[2]
    b_ = jnp.cumsum(lf, axis=-1)                      # [B,NH,c]
    a_ = ig - b_                                      # i_j - b_j
    M = jax.lax.cummax(a_, axis=2)                    # running max
    m_t = b_ + jnp.maximum(m[..., None], M)           # [B,NH,c]
    m_out = m_t[..., -1]

    # decay matrix D_tj = exp(b_t - b_j + i_j - m_t), j <= t
    D = b_[..., :, None] - b_[..., None, :] + ig[..., None, :] \
        - m_t[..., :, None]
    tri = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(tri, jnp.exp(D), 0.0)               # [B,NH,c,c]

    S = jnp.einsum("bhtd,bhjd->bhtj", q, k)           # [B,NH,c,c]
    inter_scale = jnp.exp(m[..., None] + b_ - m_t)    # [B,NH,c]
    num = jnp.einsum("bhtj,bhjd->bhtd", S * D, v) \
        + inter_scale[..., None] * jnp.einsum("bhij,bhtj->bhti", C, q)
    n_t = jnp.einsum("bhtj,bhjd->bhtd", D, k) \
        + inter_scale[..., None] * n[..., None, :]
    den = jnp.maximum(jnp.abs(jnp.einsum("bhtd,bhtd->bht", n_t, q)),
                      jnp.exp(-m_t))[..., None]
    h = num / den

    # end-of-chunk carry
    w_k = jnp.exp(b_[..., -1:] - b_ + ig - m_out[..., None])  # [B,NH,c]
    carry_scale = jnp.exp(m + b_[..., -1] - m_out)
    C_out = carry_scale[..., None, None] * C \
        + jnp.einsum("bhtd,bhte->bhde", v * w_k[..., None], k)
    n_out = carry_scale[..., None] * n \
        + jnp.einsum("bhtd,bht->bhd", k, w_k)
    return C_out, n_out, m_out, h


def apply_mlstm_seq(p, x, cfg: ModelConfig, state=None, chunk: int = 256):
    """x: [B,S,d] -> (y [B,S,d], final_state). Chunkwise-parallel: intra-
    chunk work is matmul-shaped (MXU-friendly), only the inter-chunk
    recurrence is sequential — the per-timestep scan stored O(S) states for
    the backward pass (16 TB-scale at train shapes; see EXPERIMENTS.md)."""
    di, nh, dh = mlstm_dims(cfg)
    b, s, _ = x.shape
    if state is None:
        state = init_mlstm_state(cfg, b)
    # carry the depthwise-conv window across calls (chunked prefill /
    # segment continuation must match token-by-token decode exactly)
    q, k, v, ig, lf, z, new_conv = _mlstm_qkvgates(
        p, x, cfg, conv_state=state["conv"])
    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c

    def split_chunks(t, heads=True):
        if heads:  # [B,NH,S,DH] -> [nc,B,NH,c,DH]
            return t.reshape(b, nh, nc, c, -1).transpose(2, 0, 1, 3, 4)
        return t.reshape(b, nh, nc, c).transpose(2, 0, 1, 3)

    @jax.checkpoint
    def body(carry, inp):
        # rematted: backward recomputes one chunk's [c,c] decay/score
        # matrices instead of storing them for every chunk
        C, n, m = carry
        qc, kc, vc, igc, lfc = inp
        C, n, m, h = mlstm_chunk_body(C, n, m, qc, kc, vc, igc, lfc)
        return (C, n, m), h

    (C, n, m), hs = jax.lax.scan(
        body, (state["C"], state["n"], state["m"]),
        (split_chunks(q), split_chunks(k), split_chunks(v),
         split_chunks(ig, False), split_chunks(lf, False)))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(b, nh, s, dh)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, di).astype(x.dtype)
    y = (_norm(h, p["gn"]) * jax.nn.silu(z)) @ p["w_out"]
    return y, {"C": C, "n": n, "m": m, "conv": new_conv}


def apply_mlstm_step(p, x_t, state, cfg: ModelConfig):
    """x_t: [B,1,d]."""
    di, nh, dh = mlstm_dims(cfg)
    q, k, v, ig, lf, z, new_conv = _mlstm_qkvgates(p, x_t, cfg,
                                                   conv_state=state["conv"])
    C, n, m, h = _mlstm_update(state["C"], state["n"], state["m"],
                               q[:, :, 0], k[:, :, 0], v[:, :, 0],
                               ig[:, :, 0], lf[:, :, 0])
    b = x_t.shape[0]
    h = h.reshape(b, 1, di).astype(x_t.dtype)
    y = (_norm(h, p["gn"]) * jax.nn.silu(z)) @ p["w_out"]
    return y, {"C": C, "n": n, "m": m, "conv": new_conv}


# ================================================================ sLSTM =====
def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    ks = jax.random.split(key, 3)
    dt = cfg.dtype
    return {
        "ln": jnp.ones((d,), dt),
        "w": (jax.random.normal(ks[0], (d, 4 * d)) * d ** -0.5).astype(jnp.float32),
        "r": (jax.random.normal(ks[1], (nh, dh, 4 * dh)) * dh ** -0.5).astype(jnp.float32),
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.linspace(3.0, 6.0, d),
                              jnp.zeros((2 * d,))]),
        "gn": jnp.ones((d,), dt),
        "w_out": (jax.random.normal(ks[2], (d, d)) * d ** -0.5).astype(dt),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    d, nh = cfg.d_model, cfg.num_heads
    dh = d // nh
    z = lambda: jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, nh, dh), -1e30)}


def _slstm_step(p, x_t, st, cfg):
    """x_t: [B,d] (pre-normed); heads recurrence."""
    d, nh = cfg.d_model, cfg.num_heads
    dh = d // nh
    b = x_t.shape[0]
    pre = x_t.astype(jnp.float32) @ p["w"] + p["b"]          # [B,4d]
    rec = jnp.einsum("bhj,hjk->bhk", st["h"], p["r"])        # [B,NH,4dh]
    pre = pre.reshape(b, nh, 4 * dh) + rec
    ig, fg, zg, og = jnp.split(pre, 4, axis=-1)
    log_f = -jax.nn.softplus(-fg)
    m_new = jnp.maximum(log_f + st["m"], ig)
    fs, is_ = jnp.exp(log_f + st["m"] - m_new), jnp.exp(ig - m_new)
    c = fs * st["c"] + is_ * jnp.tanh(zg)
    n = fs * st["n"] + is_
    h = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1e-6)
    return h.reshape(b, d), {"c": c, "n": n, "h": h, "m": m_new}


def apply_slstm_seq(p, x, cfg: ModelConfig, state=None, chunk: int = 128):
    """sLSTM is a true nonlinear recurrence (h_{t-1} feeds the gates through
    a matmul) — it cannot be parallelized over time. We scan chunks of
    rematerialized inner scans so the backward pass stores O(S/chunk)
    states instead of O(S)."""
    b, s, d = x.shape
    if state is None:
        state = init_slstm_state(cfg, b)
    xn = _norm(x, p["ln"])
    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c
    xc = xn.transpose(1, 0, 2).reshape(nc, c, b, d)

    def inner(st, x_t):
        h, st = _slstm_step(p, x_t, st, cfg)
        return st, h

    @jax.checkpoint
    def outer(st, xck):
        st, hs = jax.lax.scan(inner, st, xck)
        return st, hs

    state, hs = jax.lax.scan(outer, state, xc)
    h = hs.reshape(s, b, d).transpose(1, 0, 2).astype(x.dtype)
    return _norm(h, p["gn"]) @ p["w_out"], state


def apply_slstm_step(p, x_t, state, cfg: ModelConfig):
    xn = _norm(x_t, p["ln"])
    h, state = _slstm_step(p, xn[:, 0], state, cfg)
    y = _norm(h[:, None, :].astype(x_t.dtype), p["gn"]) @ p["w_out"]
    return y, state


# ================================================================ Mamba =====
def mamba_dims(cfg: ModelConfig):
    di = cfg.ssm.expand * cfg.d_model
    return di, cfg.ssm.state_size


def init_mamba(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, n = mamba_dims(cfg)
    r = max(16, d // 16)
    ks = jax.random.split(key, 7)
    dt = cfg.dtype
    return {
        "ln": jnp.ones((d,), dt),
        "w_in": (jax.random.normal(ks[0], (d, 2 * di)) * d ** -0.5).astype(dt),
        "conv": (jax.random.normal(ks[1], (cfg.ssm.conv_kernel, di)) * 0.1).astype(dt),
        "wB": (jax.random.normal(ks[2], (di, n)) * di ** -0.5).astype(dt),
        "wC": (jax.random.normal(ks[3], (di, n)) * di ** -0.5).astype(dt),
        "w_dt1": (jax.random.normal(ks[4], (di, r)) * di ** -0.5).astype(dt),
        "w_dt2": (jax.random.normal(ks[5], (r, di)) * r ** -0.5).astype(dt),
        "b_dt": jnp.full((di,), -4.6),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": (jax.random.normal(ks[6], (di, d)) * di ** -0.5).astype(dt),
    }


def init_mamba_state(cfg: ModelConfig, batch: int) -> dict:
    di, n = mamba_dims(cfg)
    return {"h": jnp.zeros((batch, di, n), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm.conv_kernel - 1, di), cfg.dtype)}


def _mamba_proj(p, x, cfg, conv_state=None):
    xz = _norm(x, p["ln"]) @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)
    if conv_state is not None:
        xi_full = jnp.concatenate([conv_state, xi], axis=1)
        new_conv = xi_full[:, -(cfg.ssm.conv_kernel - 1):, :]
        k = p["conv"].shape[0]
        xi = sum(xi_full[:, i:i + xi.shape[1], :] * p["conv"][i]
                 for i in range(k))
    else:
        new_conv_src = xi
        xi = _causal_conv(xi, p["conv"])
        new_conv = new_conv_src[:, -(cfg.ssm.conv_kernel - 1):, :] \
            if xi.shape[1] >= cfg.ssm.conv_kernel - 1 else None
    xi = jax.nn.silu(xi)
    xf = xi.astype(jnp.float32)
    dt = jax.nn.softplus(xf @ p["w_dt1"] @ p["w_dt2"] + p["b_dt"])  # [B,S,di]
    Bm = xf @ p["wB"].astype(jnp.float32)                           # [B,S,N]
    Cm = xf @ p["wC"].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                        # [di,N]
    return xf, z, dt, Bm, Cm, A, new_conv


def apply_mamba_seq(p, x, cfg: ModelConfig, state=None, chunk: int = 256):
    """Chunked selective scan: sequential carry across chunks, associative
    scan within. A full-sequence associative scan materializes
    [B,S,d_inner,N] float32 three times over — tens of GB per layer at
    train shapes; chunking bounds it to the chunk length."""
    b, s, d = x.shape
    di, n = mamba_dims(cfg)
    if state is None:
        state = init_mamba_state(cfg, b)
    xf, z, dt, Bm, Cm, A, new_conv = _mamba_proj(p, x, cfg,
                                                 conv_state=state["conv"])
    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c

    def ch(t):  # [B,S,...] -> [nc,B,c,...]
        return t.reshape((b, nc, c) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    def combine(a, b_):
        return (a[0] * b_[0], b_[0] * a[1] + b_[1])

    def body(h0, inp):
        xfc, dtc, Bc, Cc = inp
        dA = jnp.exp(dtc[..., None] * A)                  # [B,c,di,N]
        dBx = (dtc * xfc)[..., None] * Bc[:, :, None, :]
        dBx = dBx.at[:, 0].add(dA[:, 0] * h0)
        _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        yc = jnp.einsum("bsdn,bsn->bsd", hs, Cc)
        return hs[:, -1], yc

    h_fin, ys = jax.lax.scan(body, state["h"],
                             (ch(xf), ch(dt), ch(Bm), ch(Cm)))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di) + p["D"] * xf
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out, {"h": h_fin, "conv": new_conv}


def apply_mamba_step(p, x_t, state, cfg: ModelConfig):
    xf, z, dt, Bm, Cm, A, new_conv = _mamba_proj(p, x_t, cfg,
                                                 conv_state=state["conv"])
    dA = jnp.exp(dt[:, 0, :, None] * A)                             # [B,di,N]
    dBx = (dt[:, 0] * xf[:, 0])[..., None] * Bm[:, 0, None, :]
    h = dA * state["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0]) + p["D"] * xf[:, 0]
    out = (y[:, None, :].astype(x_t.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out, {"h": h, "conv": new_conv}
