"""Encoder-decoder backbone for the audio (SeamlessM4T-style) architecture.

The audio frontend (mel + conformer conv feature extractor) is a stub per the
assignment carve-out: ``input_specs`` feeds precomputed frame embeddings of
shape [B, S_enc, prefix_dim]; the model owns a projector, a bidirectional
encoder stack, and a causal decoder stack with cross-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.act_sharding import constrain
from repro.models import blocks as B


def init_enc_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "attn": B.init_attention(k1, cfg),
        "ln2": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "ffn": B.init_mlp(k2, cfg),
    }


def init_dec_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "attn": B.init_attention(k1, cfg),
        "ln_x": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "xattn": B.init_attention(k2, cfg, cross=True),
        "ln2": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "ffn": B.init_mlp(k3, cfg),
    }


def init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    ekeys = jax.random.split(ks[0], cfg.enc_layers)
    dkeys = jax.random.split(ks[1], cfg.dec_layers)
    return {
        "frontend": B.init_linear(ks[2], cfg.prefix_dim, cfg.d_model, cfg.dtype),
        "enc_blocks": jax.vmap(lambda k: init_enc_block(k, cfg))(ekeys),
        "enc_ln": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "embed": B.init_embedding(ks[3], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(k, cfg))(dkeys),
        "ln_f": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "head": B.init_linear(ks[4], cfg.d_model, cfg.vocab_size, cfg.dtype),
    }


def encode(params, cfg: ModelConfig, frames, *, window=None, remat=False):
    """frames: [B, S_enc, prefix_dim] -> memory [B, S_enc, d]."""
    x = B.linear(params["frontend"], frames.astype(cfg.dtype))
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(h, lp):
        a, _ = B.attention(lp["attn"], B.rms_norm(lp["ln1"], h, cfg.norm_eps),
                           cfg, positions=pos, causal=False, window=window,
                           positions_contiguous=True)
        h = h + a
        h = h + B.mlp(lp["ffn"], B.rms_norm(lp["ln2"], h, cfg.norm_eps))
        return constrain(h), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return B.rms_norm(params["enc_ln"], x, cfg.norm_eps)


def make_cross_kv(params, cfg: ModelConfig, memory):
    """Precompute per-decoder-layer cross K/V from encoder memory."""
    from repro.core.act_sharding import constrain_map
    nkv, hd = cfg.num_kv_heads, cfg.hd
    b, s, _ = memory.shape

    def one(lp):
        k = (memory @ lp["xattn"]["wk"]).reshape(b, s, nkv, hd).transpose(0, 2, 1, 3)
        v = (memory @ lp["xattn"]["wv"]).reshape(b, s, nkv, hd).transpose(0, 2, 1, 3)
        return k, v

    kv = jax.vmap(one)(params["dec_blocks"])  # stacked [L, B, nkv, S, hd]
    return jax.tree.map(
        lambda x: constrain_map(x, {1: "batch", 3: "seq"}), kv)


def decode(params, cfg: ModelConfig, tokens, cross_kv, *, positions=None,
           caches=None, window=None, logits_slice=None, hidden_only=False,
           remat=False):
    """tokens: [B, S_dec]; cross_kv: stacked (k, v) from make_cross_kv."""
    x = B.embed(params["embed"], tokens)
    pos_contig = True if positions is None else None
    if positions is None:
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    mem_pos = jnp.arange(cross_kv[0].shape[3], dtype=jnp.int32)

    def body(h, layer):
        lp, (ck, cv), lc = layer
        a, nc = B.attention(lp["attn"], B.rms_norm(lp["ln1"], h, cfg.norm_eps),
                            cfg, positions=positions, cache=lc, window=window,
                            positions_contiguous=pos_contig)
        h = h + a
        xa, _ = B.attention(lp["xattn"], B.rms_norm(lp["ln_x"], h, cfg.norm_eps),
                            cfg, positions=positions, cross_kv=(ck, cv),
                            cross_pos=mem_pos, causal=False)
        h = h + xa
        h = h + B.mlp(lp["ffn"], B.rms_norm(lp["ln2"], h, cfg.norm_eps))
        return constrain(h), nc

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, new_caches = jax.lax.scan(body, x,
                                 (params["dec_blocks"], cross_kv, caches))
    x = B.rms_norm(params["ln_f"], x, cfg.norm_eps)
    if logits_slice is not None:
        x = x[:, -logits_slice:]
    if hidden_only:
        return x, new_caches
    logits = B.linear(params["head"], x).astype(jnp.float32)
    return logits, new_caches


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    return B.init_kv_cache(cfg, batch, cache_len, stacked=cfg.dec_layers)
