"""Core transformer building blocks (pure-function JAX, dict pytree params).

Conventions:
  * params are nested dicts of jnp arrays; every ``init_*`` returns one.
  * activations flow as [batch, seq, d_model]; attention internals use
    [batch, heads, seq, head_dim].
  * all softmax/statistics in float32 regardless of param dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig

NEG_INF = -1e30

# optional Pallas kernel backend for self-attention (TPU fast path; on CPU
# the kernels run in interpret mode, so this is off by default here)
_KERNEL_BACKEND = False


def set_kernel_backend(on: bool) -> None:
    global _KERNEL_BACKEND
    _KERNEL_BACKEND = on


def kernel_backend() -> bool:
    return _KERNEL_BACKEND


# ---------------------------------------------------------------- norms ----
def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rms_norm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- rope ----
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [B, H, S, D]; positions: [B, S] or [S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,S,half]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    dt = cfg.dtype
    p = {
        "wq": (jax.random.normal(ks[0], (d, nq * hd)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, nkv * hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, nkv * hd)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (nq * hd, d)) * (nq * hd) ** -0.5).astype(dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)  # [B,N,S,D]


def _head_rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _contiguous_positions(positions) -> bool:
    """True iff ``positions`` is a trace-time constant describing the
    contiguous, non-negative layout the Pallas kernel's absolute-position
    masks assume (row i at q_offset + i, batch-uniform). Checked in
    numpy — jnp ops would be staged into the surrounding trace. Traced
    position arrays (packed sequences, -1 padding, per-example offsets)
    can't be checked, so they conservatively fall back to the XLA paths."""
    try:
        p = np.asarray(positions)
    except Exception:
        return False
    row = p if p.ndim == 1 else p[0]
    if p.ndim == 2 and not (p == row[None]).all():
        return False
    if row.size == 0 or row[0] < 0:
        return False
    return row.size == 1 or (np.diff(row) == 1).all()


def dense_mha(q, k, v, *, scale, q_pos, kv_pos, causal, window):
    """Reference attention. q:[B,Nq,Sq,D] k,v:[B,Nkv,Skv,D]."""
    b, nq, sq, d = q.shape
    nkv = k.shape[1]
    g = nq // nkv
    qg = q.reshape(b, nkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    mask = kv_pos[None, :] >= 0
    if causal:
        mask = mask & (kv_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return o.reshape(b, nq, sq, d)


def chunked_mha(q, k, v, *, scale, q_pos, kv_pos, causal, window,
                q_chunk=512, kv_chunk=1024):
    """Memory-efficient online-softmax attention (never materializes Sq x Skv).

    Single ``lax.scan`` over KV chunks; all Q rows are processed each
    iteration. This shape is deliberate for GSPMD: Q keeps its (sequence-
    over-``model``) sharding through the whole scan and K/V are gathered
    once per layer — a per-(q-chunk x kv-chunk) inner loop forces XLA to
    reshard Q and regather K/V on *every* iteration (measured 30x collective
    blow-up on the 16x16 mesh; see EXPERIMENTS.md §Perf).
    """
    b, nq, sq, d = q.shape
    nkv, skv = k.shape[1], k.shape[2]
    g = nq // nkv
    kc = min(kv_chunk, skv)
    while skv % kc:
        kc -= 1
    nkc = skv // kc

    from repro.core.act_sharding import constrain
    qg = constrain(q.reshape(b, nkv, g, sq, d), seq_dim=3)
    kb = k.reshape(b, nkv, nkc, kc, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, nkv, nkc, kc, d).transpose(2, 0, 1, 3, 4)
    kp = kv_pos.reshape(nkc, kc)

    m0 = constrain(jnp.full((b, nkv, g, sq), NEG_INF, jnp.float32), seq_dim=3)
    l0 = constrain(jnp.zeros((b, nkv, g, sq), jnp.float32), seq_dim=3)
    a0 = constrain(jnp.zeros((b, nkv, g, sq, d), jnp.float32), seq_dim=3)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, inp):
        # rematted: the backward pass recomputes the [*, Sq, kc] scores of
        # one chunk at a time instead of storing them for every chunk
        m, l, acc = carry
        k_blk, v_blk, kpos = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_blk,
                       preferred_element_type=jnp.float32) * scale
        mask = kpos[None, :] >= 0
        if causal:
            mask = mask & (kpos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, kp))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, nq, sq, d).astype(q.dtype)


def _adapted_matmul(p: dict, name: str, x, lora, lora_scale: float):
    """``x @ p[name]`` with the leaf's LoRA factors fused in when the
    factor subtree carries them (None = unadapted). Routes through the
    fused base+low-rank Pallas matmul so the merged weight is never
    materialized on the fine-tuning hot path."""
    f = None if lora is None else lora.get(name)
    if f is None:
        return x @ p[name]
    from repro.distill.lora import lora_linear
    return lora_linear(x, p[name], f, lora_scale)


def attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
              positions: jnp.ndarray,
              cache: Optional[dict] = None,
              causal: bool = True,
              window: Optional[int] = None,
              cross_kv: Optional[tuple] = None,
              cross_pos: Optional[jnp.ndarray] = None,
              use_chunked: Optional[bool] = None,
              block_q: Optional[int] = None,
              block_k: Optional[int] = None,
              positions_contiguous: Optional[bool] = None,
              lora: Optional[dict] = None,
              lora_scale: float = 1.0):
    """Unified attention: self (train/prefill/decode w/ cache) or cross.

    ``block_q``/``block_k`` override the Pallas kernel tile sizes
    (default ``cfg.attn_block_q``/``cfg.attn_block_k``) so e.g. the FHDP
    step can tune tiles without bypassing autodiff.

    ``positions_contiguous`` asserts that positions are row i ->
    q_offset + i (the layout the Pallas kernel's masks assume). Model
    layers pass True when they built ``positions`` from ``jnp.arange``
    themselves; when None, concrete position arrays are value-checked
    and traced ones conservatively take the XLA paths.

    ``lora``: optional factor subtree matching this block's attention
    params ({"wq": {"A", "B"} | None, ...}); adapted projections run the
    fused base+low-rank kernel with ``lora_scale`` (= alpha/rank).

    Returns (output, new_cache).
    """
    b, s, _ = x.shape
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = _adapted_matmul(p, "wq", x, lora, lora_scale)
    if "bq" in p:
        q = q + p["bq"]
    q = _split_heads(q, nq, hd)
    if "q_norm" in p:
        q = _head_rmsnorm(q, p["q_norm"], cfg.norm_eps)

    if cross_kv is not None:
        k, v = cross_kv
        kv_pos = cross_pos
        new_cache = cache
        q = q  # no rope on cross-attention queries (enc-dec convention)
    else:
        k = _adapted_matmul(p, "wk", x, lora, lora_scale)
        vv = _adapted_matmul(p, "wv", x, lora, lora_scale)
        if "bk" in p:
            k, vv = k + p["bk"], vv + p["bv"]
        k = _split_heads(k, nkv, hd)
        v = _split_heads(vv, nkv, hd)
        if "k_norm" in p:
            k = _head_rmsnorm(k, p["k_norm"], cfg.norm_eps)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if cache is not None:
            k, v, kv_pos, new_cache = update_kv_cache(cache, k, v, positions)
        else:
            kv_pos = positions if positions.ndim == 1 else positions[0]
            new_cache = None

    scale = hd ** -0.5
    q_pos1 = positions if positions.ndim == 1 else positions[0]
    # Pallas fast path (TPU; interpret-mode on CPU): contiguous self-
    # attention without a ring cache maps 1:1 onto the flash kernel
    # (fwd AND bwd — uneven lengths are padded + masked inside it).
    if positions_contiguous is None:
        positions_contiguous = _contiguous_positions(positions)
    if (kernel_backend() and cross_kv is None and cache is None
            and hd % 8 == 0 and positions_contiguous):
        from repro.kernels import ops as kops
        o = kops.flash_attention_ad(q, k, v, scale, causal, window,
                                    int(k.shape[2] - s),
                                    block_q=block_q or cfg.attn_block_q,
                                    block_k=block_k or cfg.attn_block_k)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, nq * hd)
        return (_adapted_matmul(p, "wo", o, lora,
                                lora_scale)).astype(x.dtype), new_cache
    if use_chunked is None:
        use_chunked = (s > 1024) and cross_kv is None
    if use_chunked:
        o = chunked_mha(q, k, v, scale=scale, q_pos=q_pos1, kv_pos=kv_pos,
                        causal=causal, window=window,
                        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    else:
        o = dense_mha(q, k, v, scale=scale, q_pos=q_pos1, kv_pos=kv_pos,
                      causal=causal, window=window)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, nq * hd)
    return (_adapted_matmul(p, "wo", o, lora,
                            lora_scale)).astype(x.dtype), new_cache


# ------------------------------------------------------------- kv cache ----
def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  stacked: int = 0) -> dict:
    """cache_len is the ring size (== window for sliding-window attention)."""
    shape = (batch, cfg.num_kv_heads, cache_len, cfg.hd)
    if stacked:
        shape = (stacked,) + shape
    pos_shape = (stacked, cache_len) if stacked else (cache_len,)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "pos": jnp.full(pos_shape, -1, jnp.int32),
    }


def update_kv_cache(cache: dict, k_new, v_new, positions):
    """Write new K/V at ring positions; return full cache views + new cache.

    k_new: [B, Nkv, S_new, D]; positions: [S_new] or [B, S_new] (shared ring
    index — batch-uniform positions assumed).
    """
    ring = cache["k"].shape[2]
    pos1 = positions if positions.ndim == 1 else positions[0]
    idx = pos1 % ring
    k = cache["k"].at[:, :, idx, :].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[:, :, idx, :].set(v_new.astype(cache["v"].dtype))
    pos = cache["pos"].at[idx].set(pos1)
    new_cache = {"k": k, "v": v, "pos": pos}
    return k, v, pos, new_cache


# ----------------------------------------------------------------- ffn -----
def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.dtype
    return {
        "wi": (jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(dt),
        "wg": (jax.random.normal(ks[1], (d, f)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(ks[2], (f, d)) * f ** -0.5).astype(dt),
    }


def mlp(p: dict, x: jnp.ndarray, lora: Optional[dict] = None,
        lora_scale: float = 1.0) -> jnp.ndarray:
    h = jax.nn.silu(_adapted_matmul(p, "wg", x, lora, lora_scale)) \
        * _adapted_matmul(p, "wi", x, lora, lora_scale)
    return _adapted_matmul(p, "wo", h, lora, lora_scale)


# ----------------------------------------------------------------- moe -----
def init_moe(key, cfg: ModelConfig) -> dict:
    d, e, de = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_expert
    ks = jax.random.split(key, 4)
    dt = cfg.dtype
    return {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, d, de)) * d ** -0.5).astype(dt),
        "wg": (jax.random.normal(ks[2], (e, d, de)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(ks[3], (e, de, d)) * de ** -0.5).astype(dt),
    }


def moe_block(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    """Top-k MoE with capacity-based scatter/gather dispatch.

    Never materializes a [T, E, cap] dispatch tensor (the one-hot einsum
    formulation is O(T*E*cap) memory — infeasible at 1M-token global
    batches). Tokens over capacity are dropped (contribute zero), standard
    GShard semantics. Returns (out, aux_loss).
    """
    b, s, d = x.shape
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    t = b * s
    xt = x.reshape(t, d)
    logits = xt.astype(jnp.float32) @ p["router"]          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(t * k * cfg.moe.capacity_factor / e))
    # position of each (token, choice) within its expert queue via argsort
    # ranking — the one-hot-cumsum formulation materializes a [T*k, E]
    # integer tensor (hundreds of GB at 1M-token batches)
    flat_e = gate_idx.reshape(t * k)                        # [T*k]
    order = jnp.argsort(flat_e)                             # stable
    starts = jnp.searchsorted(flat_e[order], jnp.arange(e))  # [E]
    pos_sorted = jnp.arange(t * k) - starts[flat_e[order]]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)     # overflow -> pad

    # dispatch: scatter token activations into [E*cap(+pad), d]
    from repro.core.act_sharding import constrain_map
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    xk = jnp.repeat(xt, k, axis=0)                          # [T*k, d]
    buf = buf.at[slot].set(xk, mode="drop")
    # expert-parallel: expert dim over the tensor axis (all-to-all
    # dispatch), capacity slots over the data axis — leaving cap unsharded
    # replicates every expert's work across the data axis (measured 16x
    # FLOP inflation on the 16x16 mesh; EXPERIMENTS.md §Perf).
    expert_in = constrain_map(buf[:-1].reshape(e, cap, d),
                              {0: "seq", 1: "batch"})

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["wg"])) \
        * jnp.einsum("ecd,edf->ecf", expert_in, p["wi"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"])     # [E, cap, d]
    expert_out = constrain_map(expert_out, {0: "seq", 1: "batch"})

    # combine: gather each (token, choice)'s expert output, weight, sum over k
    flat_out = jnp.concatenate(
        [expert_out.reshape(e * cap, d),
         jnp.zeros((1, d), expert_out.dtype)], axis=0)
    got = flat_out[slot].reshape(t, k, d)                   # [T, k, d]
    w = jnp.where(keep.reshape(t, k), gate_vals, 0.0).astype(got.dtype)
    out = jnp.einsum("tkd,tk->td", got, w,
                     preferred_element_type=jnp.float32).astype(x.dtype)

    # load-balance aux loss (Switch): E * sum(frac_tokens * frac_probs)
    me = probs.mean(0)                                      # [E]
    ce = jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32).mean(0)
    aux = e * jnp.sum(me * ce) * cfg.moe.aux_loss_weight
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) \
        * cfg.moe.router_z_weight
    return out.reshape(b, s, d), aux + zloss


# ------------------------------------------------------------ embedding ----
def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d)) * d ** -0.5).astype(dtype)}


def embed(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["table"][tokens]


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bsd,vd->bsv", x, p["table"],
                      preferred_element_type=jnp.float32)


def init_linear(key, din: int, dout: int, dtype, bias: bool = False) -> dict:
    p = {"w": (jax.random.normal(key, (din, dout)) * din ** -0.5).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((dout,), dtype)
    return p


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    return y + p["b"] if "b" in p else y
