"""Hymba-style hybrid blocks: parallel attention heads + Mamba heads fused by
mean of per-path norms (arXiv:2411.13676), followed by a SwiGLU FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks as B
from repro.models import recurrent as R


def init_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "attn": B.init_attention(k1, cfg),
        "mamba": R.init_mamba(k2, cfg),
        "attn_norm": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "ssm_norm": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "ln2": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "ffn": B.init_mlp(k3, cfg),
    }


def apply_block(p, x, cfg: ModelConfig, *, positions, kv_cache=None,
                ssm_state=None, window=None, step=False,
                positions_contiguous=None):
    h = B.rms_norm(p["ln1"], x, cfg.norm_eps)
    a, new_kv = B.attention(p["attn"], h, cfg, positions=positions,
                            cache=kv_cache, window=window,
                            positions_contiguous=positions_contiguous)
    if step:
        s, new_ssm = R.apply_mamba_step(p["mamba"], x, ssm_state, cfg)
    else:
        s, new_ssm = R.apply_mamba_seq(p["mamba"], x, cfg, state=ssm_state)
    fused = 0.5 * (B.rms_norm(p["attn_norm"], a, cfg.norm_eps)
                   + B.rms_norm(p["ssm_norm"], s, cfg.norm_eps))
    x = x + fused
    x = x + B.mlp(p["ffn"], B.rms_norm(p["ln2"], x, cfg.norm_eps))
    return x, new_kv, new_ssm


def init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    lkeys = jax.random.split(ks[0], cfg.num_layers)
    return {
        "embed": B.init_embedding(ks[1], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "blocks": jax.vmap(lambda k: init_block(k, cfg))(lkeys),
        "ln_f": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "head": B.init_linear(ks[2], cfg.d_model, cfg.vocab_size, cfg.dtype),
    }


def init_state(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    one = R.init_mamba_state(cfg, batch)
    ssm = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape).copy(), one)
    return {"kv": B.init_kv_cache(cfg, batch, cache_len, stacked=cfg.num_layers),
            "ssm": ssm}


def forward(params, cfg: ModelConfig, tokens, *, positions=None, states=None,
            window=None, step=False, logits_slice=None, hidden_only=False,
            remat=False, **_):
    x = B.embed(params["embed"], tokens)
    pos_contig = True if positions is None else None
    if positions is None:
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    kv = states["kv"] if states is not None else None
    ssm = states["ssm"] if states is not None else None

    from repro.core.act_sharding import constrain

    def body(h, layer):
        lp, lkv, lssm = layer
        h, nkv, nssm = apply_block(lp, h, cfg, positions=positions,
                                   kv_cache=lkv, ssm_state=lssm,
                                   window=window, step=step,
                                   positions_contiguous=pos_contig)
        return constrain(h), (nkv, nssm)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (nkv, nssm) = jax.lax.scan(body, x, (params["blocks"], kv, ssm))
    x = B.rms_norm(params["ln_f"], x, cfg.norm_eps)
    if logits_slice is not None:
        x = x[:, -logits_slice:]
    new_states = {"kv": nkv, "ssm": nssm} if states is not None else None
    if hidden_only:
        return x, new_states, jnp.zeros((), jnp.float32)
    logits = B.linear(params["head"], x).astype(jnp.float32)
    return logits, new_states, jnp.zeros((), jnp.float32)
