#!/usr/bin/env python
"""Validate perf-trajectory artifacts (BENCH_*.json) against their
schemas. Dispatches on the payload's ``bench`` field:

  * ``repartition_latency`` (BENCH_repartition.json) — beyond key/type
    checks it enforces the two invariants the runtime depends on: merged
    params bit-identical across the restage boundary, and no model units
    dropped by the template bridge.
  * ``attention_fwd_bwd`` (BENCH_attention.json) — enforces the memory
    claim of the Pallas flash-attention backward: the kernel VJP's
    peak-temp proxy stays flat in S (normalized by I/O) while the
    reference VJP's grows quadratically.
  * ``comm_fabric`` (BENCH_comm.json) — enforces the compression claim
    of the :mod:`repro.comm` fabric: hierarchical aggregation with the
    int8 codec moves >= 4x fewer upward bytes per round than flat fp32
    FedAvg while the held-out loss stays within 5%, and the simulated
    round time (link models) does not regress.
  * ``async_fabric`` (BENCH_async.json) — enforces the asynchrony claim
    of the event-time engine (:mod:`repro.comm.events`): under a
    50%-straggler fleet the clocked async merge reaches the synchronous
    run's held-out target loss >= 1.5x faster in simulated time, with
    <= 2% held-out loss regression (and no regression at 25%).
  * ``serving_tier`` (BENCH_serving.json) — enforces the serving claims
    of :mod:`repro.serve`: continuous batching over the paged KV-cache
    sustains >= 1.5x the warm tokens/s of naive static rebatching on the
    mixed-length fleet trace with bit-identical greedy streams, and the
    int8-quantized cache flips <= 2% of greedy tokens under
    teacher-forced replay.
  * ``prefill_tier`` (BENCH_prefill.json) — enforces the chunked-prefill
    claims of :mod:`repro.serve`: chunked paged prefill reaches first
    token >= 1.5x faster (sim-time p50, FLOP-proxy cost model) than the
    monolithic ``max_context``-padded baseline on a mixed short/long
    trace with bit-identical greedy streams, and the pod prefix cache
    shares template KV blocks (nonzero hit rate and pool-block savings)
    without changing a single token.
  * ``distill_fl`` (BENCH_distill.json) — enforces the two claims of the
    federated personalized distillation strategy: the (A, B) adapter
    uplink moves >= 20x fewer bytes per round than full-delta ``hier_fl``
    on the same arch/topology/codec, and every edge pod's student (base +
    pod adapter) is no worse than the global model on its own held-out
    partition (non-negative waypoint-L1 delta, strictly positive on
    average).
  * ``specdec`` (BENCH_specdec.json) — enforces the speculative-decoding
    claims of :mod:`repro.serve`: drafting with the pod's distilled
    student sustains >= 1.3x the plain greedy baseline's sim-time
    throughput with bit-identical streams on every pod, and the
    pod-matched draft's acceptance rate strictly beats the global
    (cloud-merged) draft's — personalization measured as accepted
    draft tokens.

    python scripts/validate_bench.py BENCH_repartition.json
    python scripts/validate_bench.py BENCH_attention.json
    python scripts/validate_bench.py BENCH_comm.json
    python scripts/validate_bench.py BENCH_async.json
    python scripts/validate_bench.py BENCH_serving.json
    python scripts/validate_bench.py BENCH_prefill.json
    python scripts/validate_bench.py BENCH_distill.json
    python scripts/validate_bench.py BENCH_specdec.json
"""
import json
import math
import sys

REPARTITION_TOP = {
    "bench": str, "schema_version": int, "arch": str, "mesh": list,
    "quick": bool, "fleet": list, "swift": dict, "event": dict,
    "compile_s": (int, float), "post_step_s": (int, float),
    "pre_loss": (int, float), "post_loss": (int, float), "analytic": dict,
}
REPARTITION_EVENT = {
    "step": int, "vid": int, "old_template": dict, "new_template": dict,
    "lookup_s": (int, float), "restage_s": (int, float),
    "rebuild_s": (int, float), "total_s": (int, float),
    "refresh_s": (int, float), "moved_bytes": (int, float),
    "params_identical": bool,
}

ATTENTION_TOP = {
    "bench": str, "schema_version": int, "backend": str, "interpret": bool,
    "quick": bool, "shape": dict, "block_q": int, "block_k": int,
    "points": list, "summary": dict,
}
ATTENTION_SIDE = {
    "fwd_bwd_s": (int, float), "peak_temp_bytes": int,
    "temp_over_io": (int, float),
}
COMM_TOP = {
    "bench": str, "schema_version": int, "arch": str, "quick": bool,
    "rounds": int, "local_steps": int, "topology": dict,
    "param_fp32_bytes": int, "modes": list, "summary": dict,
}
COMM_MODE = {
    "name": str, "strategy": str, "codec": str, "bytes_per_client": int,
    "uplink_bytes_per_round": int, "backhaul_bytes_per_round": int,
    "total_up_bytes_per_round": int, "sim_round_s": (int, float),
    "final_loss": (int, float),
}
MIN_INT8_UP_REDUCTION = 4.0     # the acceptance bar: int8 + edge tier
MAX_INT8_LOSS_DRIFT = 0.05      # matched final loss, within 5%

ASYNC_TOP = {
    "bench": str, "schema_version": int, "arch": str, "quick": bool,
    "sync_rounds": int, "local_steps": int,
    "compute_flops": (int, float), "severities": list, "summary": dict,
}
ASYNC_SEVERITY = {
    "severity": (int, float), "topology": str, "sync": dict,
    "async": dict, "speedup": (int, float), "loss_drift": (int, float),
}
ASYNC_SYNC = {
    "rounds": int, "sim_time_s": (int, float), "final_loss": (int, float),
}
ASYNC_ASYNC = {
    "merges": int, "sim_time_s": (int, float), "final_loss": (int, float),
    "clock": (int, float), "decay": (int, float),
    "t_target_s": (int, float), "staleness_mean": (int, float),
}
MIN_ASYNC_SPEEDUP_50 = 1.5      # the acceptance bar at 50% stragglers
MIN_ASYNC_SPEEDUP_25 = 1.0      # no regression at mild severity
MAX_ASYNC_LOSS_DRIFT = 0.02     # held-out loss no worse than sync by >2%

SERVING_TOP = {
    "bench": str, "schema_version": int, "arch": str, "quick": bool,
    "workload": dict, "modes": list, "int8": dict, "legacy": dict,
    "summary": dict,
}
SERVING_MODE = {
    "name": str, "policy": str, "cache": str, "requests": int,
    "total_new_tokens": int, "decode_steps": int, "prefills": int,
    "tokens_per_s": (int, float), "warm_tokens_per_s": (int, float),
    "p50_latency_s": (int, float), "p99_latency_s": (int, float),
    "deadline_hit_rate": (int, float),
}
SERVING_INT8 = {
    "teacher_forced_disagreement": (int, float), "positions": int,
    "max_logit_drift": (int, float),
}
MIN_CONTINUOUS_SPEEDUP = 1.5        # warm tok/s, continuous vs rebatch
MAX_INT8_GREEDY_DISAGREEMENT = 0.02  # teacher-forced flip rate

PREFILL_TOP = {
    "bench": str, "schema_version": int, "arch": str, "quick": bool,
    "workload": dict, "modes": list, "pod": dict, "summary": dict,
}
PREFILL_MODE = {
    "name": str, "requests": int, "total_new_tokens": int,
    "decode_steps": int, "prefills": int, "prefill_chunks": int,
    "prefill_padded_tokens": int, "prefill_attn_mac": int,
    "p50_ttft_s": (int, float), "p99_ttft_s": (int, float),
    "p50_queue_wait_s": (int, float), "p99_queue_wait_s": (int, float),
    "p50_latency_s": (int, float), "p99_latency_s": (int, float),
    "sim_time_s": (int, float),
}
PREFILL_POD = {
    "requests": int, "prefix_hits": int, "prefix_misses": int,
    "prefix_hit_rate": (int, float), "prefix_cached_tokens": int,
    "prefix_blocks_saved": int, "p50_ttft_s_shared": (int, float),
    "p50_ttft_s_unshared": (int, float),
    "prefill_padded_tokens_shared": int,
    "prefill_padded_tokens_unshared": int, "streams_match": bool,
}
MIN_TTFT_SPEEDUP = 1.5          # chunked vs monolithic, sim-time p50

DISTILL_TOP = {
    "bench": str, "schema_version": int, "arch": str, "quick": bool,
    "rounds": int, "local_steps": int, "topology": dict, "distill": dict,
    "adapter": dict, "full_delta": dict, "pods": list, "summary": dict,
}
DISTILL_WIRE = {
    "bytes_per_client": int, "uplink_bytes_per_round": int,
    "backhaul_bytes_per_round": int, "sim_round_s": (int, float),
}
DISTILL_POD = {
    "pod": int, "global_l1": (int, float), "pod_l1": (int, float),
    "delta": (int, float),
}
MIN_ADAPTER_UP_REDUCTION = 20.0  # adapter uplink vs full-delta hier_fl
MIN_POD_DELTA = 0.0              # no pod may lose to the global model

SPECDEC_TOP = {
    "bench": str, "schema_version": int, "arch": str, "quick": bool,
    "rounds": int, "draft_k": int, "topology": dict, "workload": dict,
    "pods": list, "summary": dict,
}
SPECDEC_BASE = {
    "decode_steps": int, "total_new_tokens": int,
    "sim_time_s": (int, float),
}
SPECDEC_DRAFT = {
    "acceptance_rate": (int, float), "proposed_drafts": int,
    "accepted_drafts": int, "spec_steps": int, "draft_forwards": int,
    "decode_steps": int, "total_new_tokens": int,
    "sim_time_s": (int, float),
}
MIN_SPECDEC_SPEEDUP = 1.3        # pod-draft sim tok/s vs plain greedy

# the kernel VJP's normalized peak may wobble (padding, residual dtype)
# but must not grow with S; the reference VJP's raw peak is the
# [B, Hkv, G, Sq, Skv] float32 score matrix, i.e. exactly quadratic.
KERNEL_FLATNESS_BOUND = 3.0
REF_QUADRATIC_SLACK = 0.5
MIN_REF_OVER_KERNEL = 2.0


def fail(msg: str) -> None:
    raise SystemExit(f"validate_bench: FAIL — {msg}")


def check_keys(obj: dict, spec: dict, where: str) -> None:
    for key, typ in spec.items():
        if key not in obj:
            fail(f"{where} missing key {key!r}")
        if not isinstance(obj[key], typ):
            fail(f"{where}[{key!r}] is {type(obj[key]).__name__}, "
                 f"expected {typ}")


def validate_repartition(data: dict, path: str) -> None:
    check_keys(data, REPARTITION_TOP, "payload")
    ev = data["event"]
    check_keys(ev, REPARTITION_EVENT, "event")

    for key in ("lookup_s", "restage_s", "rebuild_s", "total_s",
                "refresh_s"):
        if ev[key] < 0:
            fail(f"event[{key!r}] negative")
    if not ev["params_identical"]:
        fail("merged params were NOT bit-identical across the restage")
    old = sum(sum(v) for v in ev["old_template"].values())
    new = sum(sum(v) for v in ev["new_template"].values())
    if old != new or new <= 0:
        fail(f"template bridge dropped units: {old} layers -> {new}")
    for key in ("pre_loss", "post_loss"):
        if not math.isfinite(data[key]):
            fail(f"{key} is not finite")
    for key in ("template_s", "elastic_s", "relaunch_s"):
        if key not in data["analytic"]:
            fail(f"analytic missing {key!r}")

    print(f"validate_bench: OK — {path} "
          f"(live switch {ev['total_s'] * 1e3:.1f} ms, "
          f"{new} layers re-staged, params identical)")


def validate_attention(data: dict, path: str) -> None:
    check_keys(data, ATTENTION_TOP, "payload")
    points = data["points"]
    if len(points) < 2:
        fail(f"need >= 2 seq points, got {len(points)}")
    seqs = []
    for i, pt in enumerate(points):
        where = f"points[{i}]"
        if "seq" not in pt or "io_bytes" not in pt:
            fail(f"{where} missing seq/io_bytes")
        seqs.append(pt["seq"])
        for side in ("kernel", "ref"):
            if side not in pt:
                fail(f"{where} missing {side!r}")
            check_keys(pt[side], ATTENTION_SIDE, f"{where}[{side!r}]")
            if not (pt[side]["fwd_bwd_s"] > 0
                    and math.isfinite(pt[side]["fwd_bwd_s"])):
                fail(f"{where}[{side!r}] fwd_bwd_s not positive-finite")
            if pt[side]["peak_temp_bytes"] <= 0:
                fail(f"{where}[{side!r}] peak_temp_bytes <= 0")
    if seqs != sorted(seqs) or len(set(seqs)) != len(seqs):
        fail(f"seq points not strictly increasing: {seqs}")

    seq_ratio = seqs[-1] / seqs[0]
    k_toi = [pt["kernel"]["temp_over_io"] for pt in points]
    if max(k_toi) / min(k_toi) > KERNEL_FLATNESS_BOUND:
        fail("kernel VJP peak-temp proxy is NOT flat in S: temp/io spans "
             f"x{max(k_toi) / min(k_toi):.2f} "
             f"(bound x{KERNEL_FLATNESS_BOUND}) — an O(S^2) temporary is "
             "back on the training hot path")
    ref_growth = (points[-1]["ref"]["peak_temp_bytes"]
                  / points[0]["ref"]["peak_temp_bytes"])
    if ref_growth < REF_QUADRATIC_SLACK * seq_ratio ** 2:
        fail(f"reference VJP peak grew only x{ref_growth:.1f} over seq "
             f"x{seq_ratio:.0f} — the baseline being compared against is "
             "not the O(S^2) recompute")
    ratio = (points[-1]["ref"]["peak_temp_bytes"]
             / points[-1]["kernel"]["peak_temp_bytes"])
    if ratio < MIN_REF_OVER_KERNEL:
        fail(f"kernel VJP peak within x{ratio:.1f} of the reference at "
             f"seq={seqs[-1]} — no memory win")

    print(f"validate_bench: OK — {path} (seq x{seq_ratio:.0f}: kernel "
          f"temp/io flat at {max(k_toi):.2f}, ref peak x{ref_growth:.0f}, "
          f"ref/kernel x{ratio:.1f} at seq={seqs[-1]})")


def validate_comm(data: dict, path: str) -> None:
    check_keys(data, COMM_TOP, "payload")
    modes = {m.get("name"): m for m in data["modes"]}
    for want in ("flat_fp32", "hier_int8", "hier_topk"):
        if want not in modes:
            fail(f"modes missing {want!r}")
    for name, m in modes.items():
        check_keys(m, COMM_MODE, f"modes[{name!r}]")
        if not math.isfinite(m["final_loss"]):
            fail(f"modes[{name!r}] final_loss not finite")
        if m["sim_round_s"] <= 0:
            fail(f"modes[{name!r}] sim_round_s not positive")
        if m["total_up_bytes_per_round"] != (m["uplink_bytes_per_round"]
                                             + m["backhaul_bytes_per_round"]):
            fail(f"modes[{name!r}] byte totals inconsistent")
    flat, int8, topk = (modes[n] for n in ("flat_fp32", "hier_int8",
                                           "hier_topk"))
    reduction = (flat["total_up_bytes_per_round"]
                 / int8["total_up_bytes_per_round"])
    if reduction < MIN_INT8_UP_REDUCTION:
        fail(f"int8 + edge aggregation moves only x{reduction:.2f} fewer "
             f"upward bytes than flat fp32 (need >= "
             f"x{MIN_INT8_UP_REDUCTION}) — the fabric is not compressing")
    drift = abs(int8["final_loss"] / flat["final_loss"] - 1.0)
    if drift > MAX_INT8_LOSS_DRIFT:
        fail(f"int8 held-out loss drifted {drift:.1%} from flat fp32 "
             f"(bound {MAX_INT8_LOSS_DRIFT:.0%}) — compression is not "
             f"quality-matched")
    if int8["sim_round_s"] > flat["sim_round_s"]:
        fail("int8 hierarchical round is slower than flat fp32 on the "
             "same links — the link models contradict the fabric's point")
    if topk["total_up_bytes_per_round"] >= int8["total_up_bytes_per_round"]:
        fail("top-k payload is not smaller than int8 — sparsification "
             "accounting is wrong")

    print(f"validate_bench: OK — {path} (int8 x{reduction:.1f} upward "
          f"bytes vs flat fp32, loss drift {drift:.1%}, round "
          f"{flat['sim_round_s'] / int8['sim_round_s']:.1f}x faster)")


def validate_async(data: dict, path: str) -> None:
    check_keys(data, ASYNC_TOP, "payload")
    by_sev = {}
    for i, s in enumerate(data["severities"]):
        where = f"severities[{i}]"
        check_keys(s, ASYNC_SEVERITY, where)
        check_keys(s["sync"], ASYNC_SYNC, f"{where}[sync]")
        check_keys(s["async"], ASYNC_ASYNC, f"{where}[async]")
        for side in ("sync", "async"):
            if not math.isfinite(s[side]["final_loss"]):
                fail(f"{where}[{side}] final_loss not finite")
            if s[side]["sim_time_s"] <= 0:
                fail(f"{where}[{side}] sim_time_s not positive")
        if not 0 < s["async"]["t_target_s"] <= s["sync"]["sim_time_s"]:
            fail(f"{where} t_target_s outside (0, sync budget]")
        if s["async"]["merges"] <= s["sync"]["rounds"]:
            fail(f"{where}: async made {s['async']['merges']} merges in "
                 f"the sync budget vs {s['sync']['rounds']} sync rounds — "
                 "the clocked merge is not decoupled from stragglers")
        if s["loss_drift"] > MAX_ASYNC_LOSS_DRIFT:
            fail(f"{where}: async held-out loss regressed "
                 f"{s['loss_drift']:.1%} vs sync (bound "
                 f"{MAX_ASYNC_LOSS_DRIFT:.0%}) — asynchrony is not "
                 "quality-matched")
        by_sev[round(float(s["severity"]), 2)] = s
    for want in (0.25, 0.5):
        if want not in by_sev:
            fail(f"severities missing the {want:.0%}-straggler point")
    if by_sev[0.5]["speedup"] < MIN_ASYNC_SPEEDUP_50:
        fail(f"50%-straggler speedup x{by_sev[0.5]['speedup']:.2f} below "
             f"the x{MIN_ASYNC_SPEEDUP_50} acceptance bar — the async "
             "engine is not beating the straggler-gated sync round")
    if by_sev[0.25]["speedup"] < MIN_ASYNC_SPEEDUP_25:
        fail(f"25%-straggler speedup x{by_sev[0.25]['speedup']:.2f} is a "
             "regression vs sync")

    print(f"validate_bench: OK — {path} (50% stragglers: "
          f"x{by_sev[0.5]['speedup']:.1f} simulated time-to-target, "
          f"drift {by_sev[0.5]['loss_drift']:.1%}; 25%: "
          f"x{by_sev[0.25]['speedup']:.1f})")


def validate_serving(data: dict, path: str) -> None:
    check_keys(data, SERVING_TOP, "payload")
    check_keys(data["int8"], SERVING_INT8, "int8")
    modes = {m.get("name"): m for m in data["modes"]}
    for want in ("continuous_fp32", "rebatch_fp32", "continuous_int8"):
        if want not in modes:
            fail(f"modes missing {want!r}")
    for name, m in modes.items():
        check_keys(m, SERVING_MODE, f"modes[{name!r}]")
        for key in ("tokens_per_s", "warm_tokens_per_s"):
            if not (m[key] > 0 and math.isfinite(m[key])):
                fail(f"modes[{name!r}] {key} not positive-finite")
        if m["p50_latency_s"] > m["p99_latency_s"]:
            fail(f"modes[{name!r}] p50 latency exceeds p99")
        if not 0.0 <= m["deadline_hit_rate"] <= 1.0:
            fail(f"modes[{name!r}] deadline_hit_rate outside [0, 1]")
        if m["total_new_tokens"] <= 0 or m["decode_steps"] <= 0:
            fail(f"modes[{name!r}] emitted no tokens")
    cont, reb = modes["continuous_fp32"], modes["rebatch_fp32"]
    for key in ("requests", "total_new_tokens"):
        if cont[key] != reb[key]:
            fail(f"continuous and rebatch served different work "
                 f"({key}: {cont[key]} vs {reb[key]}) — the throughput "
                 "comparison is not like-for-like")
    if not data["summary"].get("streams_match"):
        fail("continuous and rebatch greedy streams differ — the "
             "scheduler changes model output, not just batching")
    if cont["decode_steps"] >= reb["decode_steps"]:
        fail(f"continuous batching ran {cont['decode_steps']} decode "
             f"steps vs rebatch's {reb['decode_steps']} — lanes are not "
             "being refilled")
    speedup = cont["warm_tokens_per_s"] / reb["warm_tokens_per_s"]
    if speedup < MIN_CONTINUOUS_SPEEDUP:
        fail(f"continuous batching sustains only x{speedup:.2f} the warm "
             f"tokens/s of naive rebatching (need >= "
             f"x{MIN_CONTINUOUS_SPEEDUP}) at mixed-length load — the "
             "scheduler is not earning its complexity")
    dis = data["int8"]["teacher_forced_disagreement"]
    if dis > MAX_INT8_GREEDY_DISAGREEMENT:
        fail(f"int8 cache flips {dis:.1%} of greedy tokens under "
             f"teacher-forced replay (bound "
             f"{MAX_INT8_GREEDY_DISAGREEMENT:.0%}) — cache quantization "
             "is not quality-matched")
    if not math.isfinite(data["int8"]["max_logit_drift"]):
        fail("int8 max_logit_drift not finite")
    if data["legacy"]["warm_tokens_per_s"] <= 0:
        fail("legacy warm_tokens_per_s not positive")

    print(f"validate_bench: OK — {path} (continuous x{speedup:.2f} warm "
          f"tok/s vs rebatch over {cont['requests']} requests, streams "
          f"identical, int8 disagreement {dis:.2%} over "
          f"{data['int8']['positions']} positions)")


def validate_prefill(data: dict, path: str) -> None:
    check_keys(data, PREFILL_TOP, "payload")
    modes = {m.get("name"): m for m in data["modes"]}
    for want in ("monolithic", "chunked"):
        if want not in modes:
            fail(f"modes missing {want!r}")
    for name, m in modes.items():
        check_keys(m, PREFILL_MODE, f"modes[{name!r}]")
        for key in ("p50_ttft_s", "p99_ttft_s", "sim_time_s"):
            if not (m[key] > 0 and math.isfinite(m[key])):
                fail(f"modes[{name!r}] {key} not positive-finite")
        if m["p50_ttft_s"] > m["p99_ttft_s"]:
            fail(f"modes[{name!r}] p50 TTFT exceeds p99")
        if m["total_new_tokens"] <= 0 or m["decode_steps"] <= 0:
            fail(f"modes[{name!r}] emitted no tokens")
    mono, chunk = modes["monolithic"], modes["chunked"]
    for key in ("requests", "total_new_tokens"):
        if mono[key] != chunk[key]:
            fail(f"monolithic and chunked served different work "
                 f"({key}: {mono[key]} vs {chunk[key]}) — the TTFT "
                 "comparison is not like-for-like")
    if mono["prefills"] <= 0 or mono["prefill_chunks"] != 0:
        fail("monolithic mode did not run monolithic prefills")
    if chunk["prefills"] != 0 or chunk["prefill_chunks"] <= 0:
        fail("chunked mode did not run chunked prefills")
    if not data["summary"].get("streams_match"):
        fail("chunked and monolithic greedy streams differ — chunked "
             "prefill changes model output, not just scheduling")
    if chunk["prefill_padded_tokens"] >= mono["prefill_padded_tokens"]:
        fail("chunked prefill pushed no fewer padded tokens than the "
             "monolithic bucket — the max_context padding is still there")
    if chunk["prefill_attn_mac"] >= mono["prefill_attn_mac"]:
        fail("chunked prefill issued no fewer attention MACs than "
             "monolithic — the block-table walk is not paying off")
    speedup = data["summary"].get("ttft_p50_speedup", 0.0)
    if abs(speedup - mono["p50_ttft_s"] / chunk["p50_ttft_s"]) > 1e-6:
        fail("summary ttft_p50_speedup inconsistent with mode TTFTs")
    if speedup < MIN_TTFT_SPEEDUP:
        fail(f"chunked prefill reaches first token only x{speedup:.2f} "
             f"faster than monolithic (need >= x{MIN_TTFT_SPEEDUP}) — "
             "chunking is not earning its complexity")
    pod = data["pod"]
    check_keys(pod, PREFILL_POD, "pod")
    if not pod["streams_match"]:
        fail("prefix sharing changed the pod trace's greedy streams — "
             "shared blocks are not bitwise the recomputed KV")
    if not 0.0 < pod["prefix_hit_rate"] <= 1.0:
        fail(f"prefix hit rate {pod['prefix_hit_rate']} not in (0, 1] on "
             "the pod-templated trace — the cache never matched")
    if pod["prefix_hits"] <= 0 or pod["prefix_blocks_saved"] <= 0:
        fail("prefix cache saved no pool blocks on the pod trace")
    if pod["prefill_padded_tokens_shared"] >= \
            pod["prefill_padded_tokens_unshared"]:
        fail("prefix sharing did not reduce prefill work on the pod "
             "trace — cached tokens are being recomputed")

    print(f"validate_bench: OK — {path} (TTFT p50 x{speedup:.2f} vs "
          f"monolithic over {mono['requests']} requests, streams "
          f"identical; pod prefix hit rate {pod['prefix_hit_rate']:.0%}, "
          f"{pod['prefix_blocks_saved']} pool blocks saved)")


def validate_distill(data: dict, path: str) -> None:
    check_keys(data, DISTILL_TOP, "payload")
    adapter, full = data["adapter"], data["full_delta"]
    check_keys(adapter, DISTILL_WIRE, "adapter")
    check_keys(full, DISTILL_WIRE, "full_delta")
    if adapter.get("rank", 0) <= 0:
        fail("adapter rank not positive")
    for side, d in (("adapter", adapter), ("full_delta", full)):
        for key in ("bytes_per_client", "uplink_bytes_per_round",
                    "backhaul_bytes_per_round"):
            if d[key] <= 0:
                fail(f"{side}[{key!r}] not positive")
        if d["sim_round_s"] <= 0:
            fail(f"{side} sim_round_s not positive")
    reduction = (full["uplink_bytes_per_round"]
                 / adapter["uplink_bytes_per_round"])
    if reduction < MIN_ADAPTER_UP_REDUCTION:
        fail(f"adapter uplink moves only x{reduction:.1f} fewer bytes "
             f"than full-delta hier_fl (need >= "
             f"x{MIN_ADAPTER_UP_REDUCTION:.0f}) — the uplink is not "
             "adapter-only")
    dist = data["distill"]
    for key in ("warmup_loss_first", "warmup_loss_last"):
        if key not in dist or not math.isfinite(dist[key]):
            fail(f"distill[{key!r}] missing or not finite")
    if dist["warmup_loss_last"] >= dist["warmup_loss_first"]:
        fail("cloud teacher warmup did not reduce the supervised loss — "
             "students are distilling from an untrained teacher")
    pods = data["pods"]
    if len(pods) != data["topology"].get("edges"):
        fail(f"{len(pods)} pod entries for "
             f"{data['topology'].get('edges')} edges")
    for p in pods:
        check_keys(p, DISTILL_POD, f"pods[{p.get('pod')}]")
        for key in ("global_l1", "pod_l1"):
            if not (p[key] > 0 and math.isfinite(p[key])):
                fail(f"pods[{p['pod']}] {key} not positive-finite")
        if abs(p["delta"] - (p["global_l1"] - p["pod_l1"])) > 1e-9:
            fail(f"pods[{p['pod']}] delta inconsistent with its losses")
        if p["delta"] < MIN_POD_DELTA:
            fail(f"pod {p['pod']}'s student loses to the global model on "
                 f"its own held-out partition (delta {p['delta']:+.4f}) "
                 "— personalization is not happening")
    mean_delta = data["summary"].get("mean_personalization_delta")
    if not (mean_delta is not None and mean_delta > 0):
        fail("mean personalization delta not positive — the per-pod "
             "adapters are indistinguishable from the cloud merge")

    print(f"validate_bench: OK — {path} (adapter uplink x{reduction:.1f} "
          f"smaller than full-delta, {len(pods)} pods all >= global, "
          f"mean delta {mean_delta:+.4f})")


def validate_specdec(data: dict, path: str) -> None:
    check_keys(data, SPECDEC_TOP, "payload")
    if data["draft_k"] <= 0:
        fail("draft_k not positive")
    pods = data["pods"]
    if len(pods) != data["topology"].get("edges"):
        fail(f"{len(pods)} pod entries for "
             f"{data['topology'].get('edges')} edges")
    for p in pods:
        where = f"pods[{p.get('pod')}]"
        check_keys(p["baseline"], SPECDEC_BASE, f"{where}[baseline]")
        for side in ("pod_draft", "global_draft"):
            d = p[side]
            check_keys(d, SPECDEC_DRAFT, f"{where}[{side}]")
            if not (d["sim_time_s"] > 0 and math.isfinite(d["sim_time_s"])):
                fail(f"{where}[{side}] sim_time_s not positive-finite")
            if not 0.0 <= d["acceptance_rate"] <= 1.0:
                fail(f"{where}[{side}] acceptance_rate outside [0, 1]")
            if d["spec_steps"] <= 0 or d["proposed_drafts"] <= 0:
                fail(f"{where}[{side}] never speculated — the draft "
                     "engine is not on the decode path")
            if d["accepted_drafts"] > d["proposed_drafts"]:
                fail(f"{where}[{side}] accepted more drafts than "
                     "proposed")
            if d["total_new_tokens"] != p["baseline"]["total_new_tokens"]:
                fail(f"{where}[{side}] served different work than the "
                     "baseline — the speedup is not like-for-like")
        if not (p["streams_match_pod"] and p["streams_match_global"]):
            fail(f"{where} speculative greedy streams differ from plain "
                 "decode — acceptance is rewriting tokens, not just "
                 "skipping steps")
        if p["speedup_pod"] < MIN_SPECDEC_SPEEDUP:
            fail(f"{where} pod-draft sim speedup x{p['speedup_pod']:.2f} "
                 f"below the x{MIN_SPECDEC_SPEEDUP} acceptance bar — "
                 "speculation is not earning its verify chunk")
        gap = (p["pod_draft"]["acceptance_rate"]
               - p["global_draft"]["acceptance_rate"])
        if gap <= 0:
            fail(f"{where} pod-matched draft acceptance does not beat "
                 f"the global draft (gap {gap:+.3f}) — the personalized "
                 "student is not a better speculator on its own traffic")
        if p["pod_draft"]["decode_steps"] >= p["baseline"]["decode_steps"]:
            fail(f"{where} pod draft took no fewer target steps than "
                 "plain decode — accepted drafts are not being emitted")
    s = data["summary"]
    if not s.get("streams_match"):
        fail("summary streams_match is false")
    if abs(s.get("min_pod_speedup", 0.0)
           - min(p["speedup_pod"] for p in pods)) > 1e-9:
        fail("summary min_pod_speedup inconsistent with pod entries")

    print(f"validate_bench: OK — {path} (pod draft x"
          f"{s['min_pod_speedup']:.2f} min sim speedup over {len(pods)} "
          f"pods, acceptance {s['mean_pod_acceptance']:.2f} vs "
          f"{s['mean_global_acceptance']:.2f} global, streams identical)")


VALIDATORS = {
    "repartition_latency": validate_repartition,
    "attention_fwd_bwd": validate_attention,
    "comm_fabric": validate_comm,
    "async_fabric": validate_async,
    "serving_tier": validate_serving,
    "prefill_tier": validate_prefill,
    "distill_fl": validate_distill,
    "specdec": validate_specdec,
}


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_repartition.json"
    with open(path) as f:
        data = json.load(f)

    bench = data.get("bench")
    validator = VALIDATORS.get(bench)
    if validator is None:
        fail(f"unknown bench name {bench!r} "
             f"(expected one of {sorted(VALIDATORS)})")
    validator(data, path)


if __name__ == "__main__":
    main()
