#!/usr/bin/env python
"""Validate BENCH_repartition.json against the perf-trajectory schema.

CI gate for the scheduler->runtime repartition path: beyond key/type
checks it enforces the two invariants the runtime depends on — merged
params bit-identical across the restage boundary, and no model units
dropped by the template bridge (old and new templates cover the same
layer count).

    python scripts/validate_bench.py BENCH_repartition.json
"""
import json
import math
import sys

TOP = {
    "bench": str, "schema_version": int, "arch": str, "mesh": list,
    "quick": bool, "fleet": list, "swift": dict, "event": dict,
    "compile_s": (int, float), "post_step_s": (int, float),
    "pre_loss": (int, float), "post_loss": (int, float), "analytic": dict,
}
EVENT = {
    "step": int, "vid": int, "old_template": dict, "new_template": dict,
    "lookup_s": (int, float), "restage_s": (int, float),
    "rebuild_s": (int, float), "total_s": (int, float),
    "refresh_s": (int, float), "moved_bytes": (int, float),
    "params_identical": bool,
}


def fail(msg: str) -> None:
    raise SystemExit(f"validate_bench: FAIL — {msg}")


def check_keys(obj: dict, spec: dict, where: str) -> None:
    for key, typ in spec.items():
        if key not in obj:
            fail(f"{where} missing key {key!r}")
        if not isinstance(obj[key], typ):
            fail(f"{where}[{key!r}] is {type(obj[key]).__name__}, "
                 f"expected {typ}")


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_repartition.json"
    with open(path) as f:
        data = json.load(f)

    check_keys(data, TOP, "payload")
    if data["bench"] != "repartition_latency":
        fail(f"unexpected bench name {data['bench']!r}")
    ev = data["event"]
    check_keys(ev, EVENT, "event")

    for key in ("lookup_s", "restage_s", "rebuild_s", "total_s",
                "refresh_s"):
        if ev[key] < 0:
            fail(f"event[{key!r}] negative")
    if not ev["params_identical"]:
        fail("merged params were NOT bit-identical across the restage")
    old = sum(sum(v) for v in ev["old_template"].values())
    new = sum(sum(v) for v in ev["new_template"].values())
    if old != new or new <= 0:
        fail(f"template bridge dropped units: {old} layers -> {new}")
    for key in ("pre_loss", "post_loss"):
        if not math.isfinite(data[key]):
            fail(f"{key} is not finite")
    for key in ("template_s", "elastic_s", "relaunch_s"):
        if key not in data["analytic"]:
            fail(f"analytic missing {key!r}")

    print(f"validate_bench: OK — {path} "
          f"(live switch {ev['total_s'] * 1e3:.1f} ms, "
          f"{new} layers re-staged, params identical)")


if __name__ == "__main__":
    main()
