#!/usr/bin/env python
"""Validate perf-trajectory artifacts (BENCH_*.json) against their
schemas. Dispatches on the payload's ``bench`` field:

  * ``repartition_latency`` (BENCH_repartition.json) — beyond key/type
    checks it enforces the two invariants the runtime depends on: merged
    params bit-identical across the restage boundary, and no model units
    dropped by the template bridge.
  * ``attention_fwd_bwd`` (BENCH_attention.json) — enforces the memory
    claim of the Pallas flash-attention backward: the kernel VJP's
    peak-temp proxy stays flat in S (normalized by I/O) while the
    reference VJP's grows quadratically.
  * ``comm_fabric`` (BENCH_comm.json) — enforces the compression claim
    of the :mod:`repro.comm` fabric: hierarchical aggregation with the
    int8 codec moves >= 4x fewer upward bytes per round than flat fp32
    FedAvg while the held-out loss stays within 5%, and the simulated
    round time (link models) does not regress.

    python scripts/validate_bench.py BENCH_repartition.json
    python scripts/validate_bench.py BENCH_attention.json
    python scripts/validate_bench.py BENCH_comm.json
"""
import json
import math
import sys

REPARTITION_TOP = {
    "bench": str, "schema_version": int, "arch": str, "mesh": list,
    "quick": bool, "fleet": list, "swift": dict, "event": dict,
    "compile_s": (int, float), "post_step_s": (int, float),
    "pre_loss": (int, float), "post_loss": (int, float), "analytic": dict,
}
REPARTITION_EVENT = {
    "step": int, "vid": int, "old_template": dict, "new_template": dict,
    "lookup_s": (int, float), "restage_s": (int, float),
    "rebuild_s": (int, float), "total_s": (int, float),
    "refresh_s": (int, float), "moved_bytes": (int, float),
    "params_identical": bool,
}

ATTENTION_TOP = {
    "bench": str, "schema_version": int, "backend": str, "interpret": bool,
    "quick": bool, "shape": dict, "block_q": int, "block_k": int,
    "points": list, "summary": dict,
}
ATTENTION_SIDE = {
    "fwd_bwd_s": (int, float), "peak_temp_bytes": int,
    "temp_over_io": (int, float),
}
COMM_TOP = {
    "bench": str, "schema_version": int, "arch": str, "quick": bool,
    "rounds": int, "local_steps": int, "topology": dict,
    "param_fp32_bytes": int, "modes": list, "summary": dict,
}
COMM_MODE = {
    "name": str, "strategy": str, "codec": str, "bytes_per_client": int,
    "uplink_bytes_per_round": int, "backhaul_bytes_per_round": int,
    "total_up_bytes_per_round": int, "sim_round_s": (int, float),
    "final_loss": (int, float),
}
MIN_INT8_UP_REDUCTION = 4.0     # the acceptance bar: int8 + edge tier
MAX_INT8_LOSS_DRIFT = 0.05      # matched final loss, within 5%

# the kernel VJP's normalized peak may wobble (padding, residual dtype)
# but must not grow with S; the reference VJP's raw peak is the
# [B, Hkv, G, Sq, Skv] float32 score matrix, i.e. exactly quadratic.
KERNEL_FLATNESS_BOUND = 3.0
REF_QUADRATIC_SLACK = 0.5
MIN_REF_OVER_KERNEL = 2.0


def fail(msg: str) -> None:
    raise SystemExit(f"validate_bench: FAIL — {msg}")


def check_keys(obj: dict, spec: dict, where: str) -> None:
    for key, typ in spec.items():
        if key not in obj:
            fail(f"{where} missing key {key!r}")
        if not isinstance(obj[key], typ):
            fail(f"{where}[{key!r}] is {type(obj[key]).__name__}, "
                 f"expected {typ}")


def validate_repartition(data: dict, path: str) -> None:
    check_keys(data, REPARTITION_TOP, "payload")
    ev = data["event"]
    check_keys(ev, REPARTITION_EVENT, "event")

    for key in ("lookup_s", "restage_s", "rebuild_s", "total_s",
                "refresh_s"):
        if ev[key] < 0:
            fail(f"event[{key!r}] negative")
    if not ev["params_identical"]:
        fail("merged params were NOT bit-identical across the restage")
    old = sum(sum(v) for v in ev["old_template"].values())
    new = sum(sum(v) for v in ev["new_template"].values())
    if old != new or new <= 0:
        fail(f"template bridge dropped units: {old} layers -> {new}")
    for key in ("pre_loss", "post_loss"):
        if not math.isfinite(data[key]):
            fail(f"{key} is not finite")
    for key in ("template_s", "elastic_s", "relaunch_s"):
        if key not in data["analytic"]:
            fail(f"analytic missing {key!r}")

    print(f"validate_bench: OK — {path} "
          f"(live switch {ev['total_s'] * 1e3:.1f} ms, "
          f"{new} layers re-staged, params identical)")


def validate_attention(data: dict, path: str) -> None:
    check_keys(data, ATTENTION_TOP, "payload")
    points = data["points"]
    if len(points) < 2:
        fail(f"need >= 2 seq points, got {len(points)}")
    seqs = []
    for i, pt in enumerate(points):
        where = f"points[{i}]"
        if "seq" not in pt or "io_bytes" not in pt:
            fail(f"{where} missing seq/io_bytes")
        seqs.append(pt["seq"])
        for side in ("kernel", "ref"):
            if side not in pt:
                fail(f"{where} missing {side!r}")
            check_keys(pt[side], ATTENTION_SIDE, f"{where}[{side!r}]")
            if not (pt[side]["fwd_bwd_s"] > 0
                    and math.isfinite(pt[side]["fwd_bwd_s"])):
                fail(f"{where}[{side!r}] fwd_bwd_s not positive-finite")
            if pt[side]["peak_temp_bytes"] <= 0:
                fail(f"{where}[{side!r}] peak_temp_bytes <= 0")
    if seqs != sorted(seqs) or len(set(seqs)) != len(seqs):
        fail(f"seq points not strictly increasing: {seqs}")

    seq_ratio = seqs[-1] / seqs[0]
    k_toi = [pt["kernel"]["temp_over_io"] for pt in points]
    if max(k_toi) / min(k_toi) > KERNEL_FLATNESS_BOUND:
        fail("kernel VJP peak-temp proxy is NOT flat in S: temp/io spans "
             f"x{max(k_toi) / min(k_toi):.2f} "
             f"(bound x{KERNEL_FLATNESS_BOUND}) — an O(S^2) temporary is "
             "back on the training hot path")
    ref_growth = (points[-1]["ref"]["peak_temp_bytes"]
                  / points[0]["ref"]["peak_temp_bytes"])
    if ref_growth < REF_QUADRATIC_SLACK * seq_ratio ** 2:
        fail(f"reference VJP peak grew only x{ref_growth:.1f} over seq "
             f"x{seq_ratio:.0f} — the baseline being compared against is "
             "not the O(S^2) recompute")
    ratio = (points[-1]["ref"]["peak_temp_bytes"]
             / points[-1]["kernel"]["peak_temp_bytes"])
    if ratio < MIN_REF_OVER_KERNEL:
        fail(f"kernel VJP peak within x{ratio:.1f} of the reference at "
             f"seq={seqs[-1]} — no memory win")

    print(f"validate_bench: OK — {path} (seq x{seq_ratio:.0f}: kernel "
          f"temp/io flat at {max(k_toi):.2f}, ref peak x{ref_growth:.0f}, "
          f"ref/kernel x{ratio:.1f} at seq={seqs[-1]})")


def validate_comm(data: dict, path: str) -> None:
    check_keys(data, COMM_TOP, "payload")
    modes = {m.get("name"): m for m in data["modes"]}
    for want in ("flat_fp32", "hier_int8", "hier_topk"):
        if want not in modes:
            fail(f"modes missing {want!r}")
    for name, m in modes.items():
        check_keys(m, COMM_MODE, f"modes[{name!r}]")
        if not math.isfinite(m["final_loss"]):
            fail(f"modes[{name!r}] final_loss not finite")
        if m["sim_round_s"] <= 0:
            fail(f"modes[{name!r}] sim_round_s not positive")
        if m["total_up_bytes_per_round"] != (m["uplink_bytes_per_round"]
                                             + m["backhaul_bytes_per_round"]):
            fail(f"modes[{name!r}] byte totals inconsistent")
    flat, int8, topk = (modes[n] for n in ("flat_fp32", "hier_int8",
                                           "hier_topk"))
    reduction = (flat["total_up_bytes_per_round"]
                 / int8["total_up_bytes_per_round"])
    if reduction < MIN_INT8_UP_REDUCTION:
        fail(f"int8 + edge aggregation moves only x{reduction:.2f} fewer "
             f"upward bytes than flat fp32 (need >= "
             f"x{MIN_INT8_UP_REDUCTION}) — the fabric is not compressing")
    drift = abs(int8["final_loss"] / flat["final_loss"] - 1.0)
    if drift > MAX_INT8_LOSS_DRIFT:
        fail(f"int8 held-out loss drifted {drift:.1%} from flat fp32 "
             f"(bound {MAX_INT8_LOSS_DRIFT:.0%}) — compression is not "
             f"quality-matched")
    if int8["sim_round_s"] > flat["sim_round_s"]:
        fail("int8 hierarchical round is slower than flat fp32 on the "
             "same links — the link models contradict the fabric's point")
    if topk["total_up_bytes_per_round"] >= int8["total_up_bytes_per_round"]:
        fail("top-k payload is not smaller than int8 — sparsification "
             "accounting is wrong")

    print(f"validate_bench: OK — {path} (int8 x{reduction:.1f} upward "
          f"bytes vs flat fp32, loss drift {drift:.1%}, round "
          f"{flat['sim_round_s'] / int8['sim_round_s']:.1f}x faster)")


VALIDATORS = {
    "repartition_latency": validate_repartition,
    "attention_fwd_bwd": validate_attention,
    "comm_fabric": validate_comm,
}


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_repartition.json"
    with open(path) as f:
        data = json.load(f)

    bench = data.get("bench")
    validator = VALIDATORS.get(bench)
    if validator is None:
        fail(f"unknown bench name {bench!r} "
             f"(expected one of {sorted(VALIDATORS)})")
    validator(data, path)


if __name__ == "__main__":
    main()
