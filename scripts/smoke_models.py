"""Quick manual smoke: every reduced arch runs loss + grad + decode.

Configs resolve through :func:`repro.api.load_config`; no per-script
mesh/XLA wiring.
"""
import sys

import jax
import jax.numpy as jnp

from repro.api import load_config
from repro.config import ShapeConfig
from repro.configs import ARCH_IDS
from repro.configs.common import concrete_batch
from repro.models import build_model

SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")
DECODE_SHAPE = ShapeConfig("smoke-dec", 64, 2, "decode")


def main():
    key = jax.random.PRNGKey(0)
    failures = []
    for arch in ARCH_IDS:
        cfg = load_config(arch)
        model = build_model(cfg)
        try:
            params = model.init(key)
            n = sum(x.size for x in jax.tree.leaves(params))
            batch = concrete_batch(cfg, SMOKE_SHAPE, key)
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                                 for g in jax.tree.leaves(grads)))
            ok = bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gnorm))
            msg = f"{arch:24s} params={n:9d} loss={float(loss):8.4f} gnorm={float(gnorm):10.4f}"
            # decode
            if cfg.family != "vision":
                st = model.init_state(2, 64)
                tok = jnp.zeros((2, 1), jnp.int32)
                logits, st = model.decode_step(params, tok, st, 5)
                ok = ok and bool(jnp.all(jnp.isfinite(logits)))
                msg += f" dec_logits={logits.shape}"
            print(("OK  " if ok else "BAD ") + msg)
            if not ok:
                failures.append(arch)
        except Exception as e:  # noqa
            import traceback
            traceback.print_exc()
            print(f"FAIL {arch}: {type(e).__name__}: {e}")
            failures.append(arch)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("all ok")


if __name__ == "__main__":
    main()
