#!/usr/bin/env bash
# Tier-1 CI: test suite + API smoke drivers.
# Usage: scripts/ci.sh [--fast]   (--fast skips the smoke drivers)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 pytest ==="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
  echo "=== smoke: models (repro.api.load_config) ==="
  python scripts/smoke_models.py

  echo "=== smoke: FHDP pipeline (repro.api.Session) ==="
  python scripts/smoke_pipeline.py

  echo "=== smoke: train launcher (Session CLI) ==="
  python -m repro.launch.train --strategy pipeline --devices 8 --steps 2

  echo "=== smoke: hierarchical FL over the comm fabric ==="
  python -m repro.launch.train --strategy hier_fl --devices 2 --mesh 2 \
      --topology "2@nano*2,agx*2" --codec int8 --steps 2

  echo "=== smoke: async event-time FL (clocked merge + migration) ==="
  python -m repro.launch.train --strategy async_hier_fl --devices 2 \
      --mesh 2 --topology "2@nano*2,agx*2" --codec int8 \
      --async-clock 0.3 --migrate-every 0.5 --compute-jitter 0.2 --steps 2

  echo "=== smoke: federated personalized distillation (LoRA uplinks) ==="
  python -m repro.launch.train --strategy distill_fl --arch flad-adllm \
      --shape 16x8 --devices 2 --mesh 2 --topology "2@nano*2,agx*2" \
      --codec int8 --steps 2 --distill-warmup 4

  echo "=== smoke: async FL migration example ==="
  python examples/async_fl_migration.py --rounds 3

  echo "=== smoke: traced async round (repro.obs example) ==="
  python examples/traced_async_round.py --rounds 2 \
      --out /tmp/ci_traced_async.json
  python scripts/validate_trace.py /tmp/ci_traced_async.json

  echo "=== smoke: traced async FL via the train launcher ==="
  python -m repro.launch.train --strategy async_hier_fl --devices 2 \
      --mesh 2 --topology "2@nano*2,agx*2" --codec int8 \
      --async-clock 0.3 --compute-jitter 0.2 --steps 2 \
      --trace /tmp/ci_async_trace.json --metrics /tmp/ci_async_metrics.json
  python scripts/validate_trace.py /tmp/ci_async_trace.json

  echo "=== smoke: serve launcher (Session.serve) ==="
  python -m repro.launch.serve --devices 2 --batch 2 --context 16 \
      --decode-steps 4 --requests 1

  echo "=== smoke: continuous-batching serve (paged KV tier) ==="
  python -m repro.launch.serve --devices 2 --scheduler continuous \
      --slots 2 --context 16 --requests 4 --block-size 8 --cache int8

  echo "=== smoke: chunked prefill + prefix cache (serve launcher) ==="
  python -m repro.launch.serve --devices 2 --scheduler continuous \
      --slots 2 --context 16 --requests 4 --block-size 8 \
      --prefill chunked --prefill-chunk 8 --prefix-cache

  echo "=== smoke: speculative decoding (draft-verify serve) ==="
  python -m repro.launch.serve --devices 2 --scheduler continuous \
      --slots 2 --context 16 --requests 4 --block-size 8 \
      --prefill chunked --prefill-chunk 8 --speculative --draft-k 4

  echo "=== smoke: traced continuous serve (repro.obs) ==="
  python -m repro.launch.serve --devices 2 --scheduler continuous \
      --slots 2 --context 16 --requests 4 --block-size 8 \
      --prefill chunked --prefill-chunk 8 --prefix-cache \
      --trace /tmp/ci_serve_trace.json
  python scripts/validate_trace.py /tmp/ci_serve_trace.json

  echo "=== smoke: benchmark registry listing ==="
  python benchmarks/run.py --list

  echo "=== smoke: SWIFT live repartition example (dry run) ==="
  python examples/swift_repartition.py --dry-run

  echo "=== bench: repartition latency (quick, scratch output) ==="
  # scratch path: never clobber the committed full-run perf artifacts
  python benchmarks/repartition_latency.py --quick \
      --out /tmp/BENCH_repartition.quick.json
  python scripts/validate_bench.py /tmp/BENCH_repartition.quick.json

  echo "=== bench: attention fwd+bwd (quick, scratch output) ==="
  python benchmarks/attention_bench.py --quick \
      --out /tmp/BENCH_attention.quick.json
  python scripts/validate_bench.py /tmp/BENCH_attention.quick.json

  echo "=== bench: comm fabric (quick, scratch output) ==="
  python benchmarks/comm_bench.py --quick --out /tmp/BENCH_comm.quick.json
  python scripts/validate_bench.py /tmp/BENCH_comm.quick.json

  echo "=== bench: async event-time engine (quick, scratch output) ==="
  python benchmarks/async_bench.py --quick \
      --out /tmp/BENCH_async.quick.json
  python scripts/validate_bench.py /tmp/BENCH_async.quick.json

  echo "=== bench: serving tier (quick, scratch output) ==="
  python benchmarks/serving_bench.py --quick \
      --out /tmp/BENCH_serving.quick.json
  python scripts/validate_bench.py /tmp/BENCH_serving.quick.json

  echo "=== bench: chunked prefill + prefix cache (quick, scratch) ==="
  python benchmarks/prefill_bench.py --quick \
      --out /tmp/BENCH_prefill.quick.json
  python scripts/validate_bench.py /tmp/BENCH_prefill.quick.json

  echo "=== bench: personalized distillation (quick, scratch output) ==="
  python benchmarks/distill_fl_bench.py --quick \
      --out /tmp/BENCH_distill.quick.json
  python scripts/validate_bench.py /tmp/BENCH_distill.quick.json

  echo "=== bench: speculative decoding (quick, scratch output) ==="
  python benchmarks/specdec_bench.py --quick \
      --out /tmp/BENCH_specdec.quick.json
  python scripts/validate_bench.py /tmp/BENCH_specdec.quick.json

  echo "=== validate committed perf-trajectory artifacts ==="
  python scripts/validate_bench.py BENCH_repartition.json
  python scripts/validate_bench.py BENCH_attention.json
  python scripts/validate_bench.py BENCH_comm.json
  python scripts/validate_bench.py BENCH_async.json
  python scripts/validate_bench.py BENCH_serving.json
  python scripts/validate_bench.py BENCH_prefill.json
  python scripts/validate_bench.py BENCH_distill.json
  python scripts/validate_bench.py BENCH_specdec.json
fi

echo "CI OK"
