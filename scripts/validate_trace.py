#!/usr/bin/env python
"""Validate a Chrome trace-event JSON file (repro.obs.trace output).

Checks the structural contract Perfetto / chrome://tracing rely on:

  * top level is ``{"traceEvents": [...]}``;
  * every event has a ``ph`` from the emitted set {X, i, M, s, f, C},
    integer ``pid``/``tid``, and a non-empty ``name``;
  * non-metadata events carry a numeric ``ts >= 0``;
  * ``X`` spans carry a numeric ``dur >= 0``;
  * ``M`` rows are known metadata (process_name / thread_name /
    process_sort_index) with the matching ``args`` payload;
  * ``s``/``f`` flow arrows pair up by ``id`` — every ``f`` has a prior
    ``s`` with the same id, no id is opened twice, none is left open,
    and the ``f`` end does not precede its ``s`` start;
  * ``C`` counter samples carry numeric-valued ``args``.

Usage::

    python scripts/validate_trace.py TRACE.json [TRACE2.json ...]

Exits non-zero (listing every violation) if any file fails. Importable:
``validate(events) -> list of error strings``.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

ALLOWED_PH = {"X", "i", "M", "s", "f", "C"}
ALLOWED_META = {"process_name", "thread_name", "process_sort_index"}
META_ARG = {"process_name": "name", "thread_name": "name",
            "process_sort_index": "sort_index"}


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate(events: List[Dict]) -> List[str]:
    """All structural violations in one pass (empty list = valid)."""
    errors: List[str] = []
    open_flows: Dict[object, float] = {}
    closed: set = set()
    for n, ev in enumerate(events):
        where = f"event[{n}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ALLOWED_PH:
            errors.append(f"{where}: unknown ph {ph!r}")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where} ({ph}): missing/empty name")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                errors.append(f"{where} ({ph} {name!r}): non-integer {k}")
        if ph == "M":
            if name not in ALLOWED_META:
                errors.append(f"{where}: unknown metadata row {name!r}")
            elif META_ARG[name] not in ev.get("args", {}):
                errors.append(f"{where} (M {name!r}): args missing "
                              f"{META_ARG[name]!r}")
            continue
        ts = ev.get("ts")
        if not _num(ts) or ts < 0:
            errors.append(f"{where} ({ph} {name!r}): bad ts {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not _num(dur) or dur < 0:
                errors.append(f"{where} (X {name!r}): bad dur {dur!r}")
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                errors.append(f"{where} (C {name!r}): missing args")
            else:
                for k, v in args.items():
                    if not _num(v):
                        errors.append(f"{where} (C {name!r}): "
                                      f"non-numeric series {k}={v!r}")
        elif ph == "s":
            fid = ev.get("id")
            if fid is None:
                errors.append(f"{where} (s {name!r}): missing flow id")
            elif fid in open_flows or fid in closed:
                errors.append(f"{where} (s {name!r}): flow id {fid!r} "
                              f"reused")
            else:
                open_flows[fid] = ts
        elif ph == "f":
            fid = ev.get("id")
            if fid not in open_flows:
                errors.append(f"{where} (f {name!r}): flow id {fid!r} "
                              f"has no prior s")
            else:
                if ts < open_flows[fid]:
                    errors.append(f"{where} (f {name!r}): flow id "
                                  f"{fid!r} ends before its start")
                if ev.get("bp") != "e":
                    errors.append(f"{where} (f {name!r}): missing "
                                  f"bp='e' (Perfetto needs it to bind "
                                  f"the arrow to the enclosing slice)")
                del open_flows[fid]
                closed.add(fid)
    for fid, ts in sorted(open_flows.items(), key=lambda kv: str(kv[0])):
        errors.append(f"flow id {fid!r} (s at ts={ts}) never finished")
    return errors


def validate_file(path: str) -> List[str]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot load: {e}"]
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["top level must be an object with 'traceEvents'"]
    if not isinstance(doc["traceEvents"], list):
        return ["'traceEvents' must be a list"]
    return validate(doc["traceEvents"])


def main(argv: List[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    bad = 0
    for path in argv:
        errors = validate_file(path)
        if errors:
            bad += 1
            print(f"[validate_trace] FAIL {path}: {len(errors)} "
                  f"violation(s)")
            for e in errors[:50]:
                print(f"  - {e}")
            if len(errors) > 50:
                print(f"  ... and {len(errors) - 50} more")
        else:
            with open(path) as f:
                n = len(json.load(f)["traceEvents"])
            print(f"[validate_trace] OK   {path}: {n} events")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
