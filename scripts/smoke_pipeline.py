"""Smoke: FHDP pipeline loss == single-device loss at step 0, per family."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp

from repro.config import ShapeConfig
from repro.configs import ARCH_IDS, get_config
from repro.configs.common import concrete_batch, reduced
from repro.core import pipeline as pl
from repro.core.fhdp import init_fhdp
from repro.launch.mesh import make_test_mesh
from repro.models import build_model

ARCHS = ["qwen3_14b", "qwen3_moe_30b_a3b", "xlstm_350m", "hymba_1_5b",
         "seamless_m4t_large_v2", "internvl2_2b", "flad_vision"]


def main():
    mesh = make_test_mesh(data=2, model=4)
    fails = []
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        shape = ShapeConfig("smoke", 64, 8, "train")
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        batch = concrete_batch(cfg, shape, key)

        ref_loss, _ = model.loss(params, batch, remat=False)

        step, h = pl.make_fhdp_train_step(cfg, shape, mesh, remat=True,
                                          learning_rate=1e-3)
        templates = h["templates"]
        pp = pl.stage_params_from(params, cfg, templates)
        opt = pl.zero2_init(pp, mesh.shape["data"])
        jstep = jax.jit(step)
        pp2, opt2, metrics = jstep(pp, opt, batch)
        got = float(metrics["loss"])
        ref = float(ref_loss)
        # second step: loss should change (params updated) and stay finite
        _, _, m2 = jstep(pp2, opt2, batch)
        ok = abs(got - ref) / max(abs(ref), 1e-6) < 2e-2 and \
            jnp.isfinite(jnp.asarray(m2["loss"]))
        print(("OK  " if ok else "BAD ")
              + f"{arch:24s} pipeline={got:.5f} ref={ref:.5f} "
                f"step2={float(m2['loss']):.5f} M={h['microbatches']} "
                f"mb={h['mb']} tmpl={templates}")
        if not ok:
            fails.append(arch)
    if fails:
        print("FAILURES:", fails)
        sys.exit(1)
    print("all ok")


if __name__ == "__main__":
    main()
