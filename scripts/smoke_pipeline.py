"""Smoke: FHDP pipeline loss == single-device loss at step 0, per family.

Each arch stands up a pipeline :class:`repro.api.Session` on a
(data=2, model=4) mesh; the reference loss comes from the same params on
the flat model.
"""
import sys

import jax
import jax.numpy as jnp

from repro.api import MeshSpec, Session, load_config
from repro.config import ShapeConfig
from repro.configs.common import concrete_batch
from repro.models import build_model

ARCHS = ["qwen3_14b", "qwen3_moe_30b_a3b", "xlstm_350m", "hymba_1_5b",
         "seamless_m4t_large_v2", "internvl2_2b", "flad_vision"]


def main():
    # build the mesh before any other jax device use: MeshSpec forces the
    # 8 host devices only if it runs before the first backend init
    mesh = MeshSpec((2, 4)).build()
    fails = []
    for arch in ARCHS:
        cfg = load_config(arch)
        shape = ShapeConfig("smoke", 64, 8, "train")
        key = jax.random.PRNGKey(0)
        batch = concrete_batch(cfg, shape, key)

        # init_fhdp and build_model share the init key -> identical params
        model = build_model(cfg)
        ref = float(model.loss(model.init(key), batch, remat=False)[0])

        session = Session(cfg=cfg, strategy="pipeline", shape=shape,
                          mesh=mesh, learning_rate=1e-3)
        step, (pp, opt) = session.build(key)
        h = session.strategy.helpers
        pp2, opt2, metrics = step(pp, opt, batch)
        got = float(metrics["loss"])
        # second step: loss should change (params updated) and stay finite
        _, _, m2 = step(pp2, opt2, batch)
        ok = abs(got - ref) / max(abs(ref), 1e-6) < 2e-2 and \
            jnp.isfinite(jnp.asarray(m2["loss"]))
        print(("OK  " if ok else "BAD ")
              + f"{arch:24s} pipeline={got:.5f} ref={ref:.5f} "
                f"step2={float(m2['loss']):.5f} M={h['microbatches']} "
                f"mb={h['mb']} tmpl={session.strategy.templates}")
        if not ok:
            fails.append(arch)
    if fails:
        print("FAILURES:", fails)
        sys.exit(1)
    print("all ok")


if __name__ == "__main__":
    main()
